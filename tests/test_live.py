"""repro.live: delta-overlay semantics vs a naive set oracle (property
tests over random insert/delete/compact interleavings), fused overlay
queries vs the full-algebra oracle, post-compaction byte-identity across
eager / streamed / kgz-chain stores, delta snapshot lineage, the
generation-keyed ``open_store`` cache, and the live wire ops."""

import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # test image without hypothesis: seeded-example fallback
    from _hypothesis_shim import given, settings, st

from repro.core.executor import create_kg
from repro.data.terms import canonical_term
from repro.kg import persist, solve, parse_bgp
from repro.kg.store import TripleStore
from repro.live import LiveStore
from repro.obs import MetricsRegistry
from repro.rml import generator
from repro.serve import oracle_select, parse_select
from repro.serve.client import connect
from repro.serve.server import KGServer

# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

SUBS = [f"<http://ex/s{i}>" for i in range(5)]
PREDS = [f"<http://ex/p{i}>" for i in range(3)]
LITS = ['"1"', '"2"', '"10"', '"2.5"', '"-3"', '"abc"', '"b c"', '""']
OBJS = SUBS[:2] + LITS
# terms the base graph can never contain: inserts through these exercise
# the overlay term table (ids past the base's)
NEW_SUBS = [f"<http://ex/new{i}>" for i in range(3)]
NEW_LITS = ['"zz9"', '"7.5"']

TEMPLATES = [
    "SELECT ?s ?p ?o WHERE { ?s ?p ?o }",
    "SELECT ?s ?o WHERE { ?s <http://ex/p0> ?o }",
    "SELECT ?s ?o ?t WHERE { ?s <http://ex/p0> ?o . ?s <http://ex/p1> ?t }",
    "SELECT ?s ?o ?t WHERE { ?s <http://ex/p0> ?o "
    "OPTIONAL { ?s <http://ex/p2> ?t } }",
    "SELECT ?s ?o WHERE { { ?s <http://ex/p0> ?o } UNION "
    "{ ?s <http://ex/p1> ?o } }",
    "SELECT ?s (COUNT(*) AS ?n) WHERE { ?s ?p ?o } GROUP BY ?s",
    "SELECT DISTINCT ?s WHERE { ?s ?p ?o }",
    'SELECT ?s WHERE { ?s <http://ex/p0> ?o FILTER(?o >= 2) }',
]


def rand_base(rng, n_triples: int) -> list:
    triples = {
        (
            SUBS[rng.integers(0, len(SUBS))],
            PREDS[rng.integers(0, len(PREDS))],
            OBJS[rng.integers(0, len(OBJS))],
        )
        for _ in range(n_triples)
    }
    return sorted(triples)


def rand_triple(rng, model):
    """A triple to mutate with: half the time one that exists (so deletes
    hit), else a fresh draw over the widened (overlay-term) universe."""
    if model and rng.integers(0, 2) == 0:
        return sorted(model)[int(rng.integers(0, len(model)))]
    return (
        (SUBS + NEW_SUBS)[rng.integers(0, len(SUBS) + len(NEW_SUBS))],
        PREDS[rng.integers(0, len(PREDS))],
        (OBJS + NEW_LITS)[rng.integers(0, len(OBJS) + len(NEW_LITS))],
    )


def rand_ops(rng, model, n_ops: int):
    """Random (op, triples) interleaving; ``model`` (a set of canonical
    triples — the naive oracle) is updated alongside."""
    ops = []
    for _ in range(n_ops):
        kind = ("insert", "insert", "delete", "delete", "compact")[
            int(rng.integers(0, 5))
        ]
        if kind == "compact":
            ops.append(("compact", None))
            continue
        trips = [rand_triple(rng, model) for _ in range(rng.integers(1, 4))]
        ops.append((kind, trips))
        for t in trips:
            ct = tuple(canonical_term(x) for x in t)
            (model.add if kind == "insert" else model.discard)(ct)
    return ops


def apply_ops(live: LiveStore, ops) -> None:
    for kind, trips in ops:
        if kind == "insert":
            live.insert(trips)
        elif kind == "delete":
            live.delete(trips)
        else:
            live.compact()


def row_key(row):
    # overlay term ids are not rendered-order ranks, so pre-compaction
    # engine row order differs from the oracle's: compare as multisets
    return tuple((v is None, isinstance(v, int), str(v)) for v in row)


def as_multiset(rows):
    out = {}
    for r in rows:
        k = row_key(r)
        out[k] = out.get(k, 0) + 1
    return out


def check_queries(live: LiveStore) -> None:
    for qtext in TEMPLATES:
        q = parse_select(qtext)
        got = live.solve(q).rows(0)
        want = oracle_select(live, q)
        assert as_multiset(got) == as_multiset(want), (
            f"{qtext}\n got: {got}\nwant: {want}"
        )
        again = live.solve(q).rows(0)
        assert got == again, f"nondeterministic answer for {qtext}"


# --------------------------------------------------------------------------
# property tests: random interleavings vs the naive set oracle
# --------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_live_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    base_trips = rand_base(rng, int(rng.integers(0, 25)))
    live = LiveStore(TripleStore.from_ntriples(base_trips))
    model = {tuple(canonical_term(x) for x in t) for t in base_trips}
    ops = rand_ops(rng, model, n_ops=int(rng.integers(1, 7)))
    apply_ops(live, ops)
    # the set oracle: the live triple set is exactly the model set
    assert set(live.rendered_triples()) == model
    assert live.n_triples == len(model)
    check_queries(live)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_compaction_byte_identity(seed):
    """A compacted store is byte-identical (via the deterministic snapshot
    writer) to a from-scratch build of the same triple set."""
    import tempfile

    rng = np.random.default_rng(seed + 77)
    base_trips = rand_base(rng, int(rng.integers(1, 25)))
    live = LiveStore(TripleStore.from_ntriples(base_trips))
    model = {tuple(canonical_term(x) for x in t) for t in base_trips}
    apply_ops(live, rand_ops(rng, model, n_ops=int(rng.integers(1, 6))))
    compacted = live.compact()
    rebuilt = TripleStore.from_ntriples(sorted(model))
    with tempfile.TemporaryDirectory() as td:
        pa, pb = os.path.join(td, "a.kgz"), os.path.join(td, "b.kgz")
        persist.save(compacted, pa, generation=7)
        persist.save(rebuilt, pb, generation=7)
        with open(pa, "rb") as f:
            ba = f.read()
        with open(pb, "rb") as f:
            bb = f.read()
    assert ba == bb
    # post-compaction ids are canonical: answers match the oracle exactly,
    # including row order
    for qtext in TEMPLATES:
        q = parse_select(qtext)
        assert live.solve(q).rows(0) == oracle_select(live, q)


# --------------------------------------------------------------------------
# byte-identity across store construction paths
# --------------------------------------------------------------------------


@pytest.mark.slow
def test_compaction_identical_across_builds(tmp_path):
    """The same mutation sequence over an eager-built, a streamed, and a
    kgz-chain-loaded store compacts to byte-identical snapshots."""
    tb = generator.make_testbed("SOM", 40, 0.5, n_poms=2, seed=3)
    tables = {"csv:child.csv": tb.child}
    if tb.parent is not None:
        tables["csv:parent.csv"] = tb.parent
    eager = create_kg(tb.doc, tables=tables).to_store()
    streamed = create_kg(
        tb.doc, tables=tables, stream=True, block_rows=16
    ).to_store()

    rng = np.random.default_rng(5)
    model = set(
        LiveStore(eager).rendered_triples()
    )  # same graph for all three
    ops = rand_ops(rng, model, n_ops=5)

    base_path = str(tmp_path / "base.kgz")
    persist.save(eager, base_path)
    lives = {
        "eager": LiveStore(eager),
        "streamed": LiveStore(streamed),
    }
    # kgz chain: apply ops to a fresh live store over the saved base,
    # snapshot the net overlay, and resolve it back through load_chain
    chain_src = LiveStore(persist.open_store(base_path))
    mut_only = [op for op in ops if op[0] != "compact"]
    apply_ops(chain_src, mut_only)
    delta_path = str(tmp_path / "delta.kgz")
    persist.save_delta(chain_src, delta_path, "base.kgz")
    chain = persist.load_chain(delta_path)
    for op_kind, _ in ops:
        if op_kind == "compact":
            chain.compact()
    lives["chain"] = chain

    blobs = {}
    for name, lv in lives.items():
        if name != "chain":
            apply_ops(lv, ops)
        assert set(lv.rendered_triples()) == model, name
        compacted = lv.compact()
        path = str(tmp_path / f"{name}.kgz")
        persist.save(compacted, path, generation=9)
        with open(path, "rb") as f:
            blobs[name] = f.read()
    assert blobs["eager"] == blobs["streamed"] == blobs["chain"]


# --------------------------------------------------------------------------
# delta snapshots / lineage
# --------------------------------------------------------------------------


def _tiny_live():
    base = TripleStore.from_ntriples(
        [
            ("<http://ex/s0>", "<http://ex/p0>", '"1"'),
            ("<http://ex/s1>", "<http://ex/p0>", '"2"'),
        ]
    )
    return LiveStore(base)


def test_delta_snapshot_roundtrip(tmp_path):
    live = _tiny_live()
    base_path = str(tmp_path / "base.kgz")
    persist.save(live.base, base_path)
    live.insert([("<http://ex/new0>", "<http://ex/p1>", '"9"')])
    live.delete([("<http://ex/s1>", "<http://ex/p0>", '"2"')])
    delta_path = str(tmp_path / "delta.kgz")
    persist.save_delta(live, delta_path, "base.kgz")
    version, n_ins, gen, kind = persist.peek_meta(delta_path)
    assert (version, n_ins, kind) == (persist.FORMAT_VERSION, 1, 1)
    assert gen == live.generation
    loaded = persist.load_chain(delta_path)
    assert set(loaded.rendered_triples()) == set(live.rendered_triples())
    assert loaded.generation == live.generation
    # load() must refuse a delta file (load_chain is the resolver)
    with pytest.raises(ValueError, match="delta snapshot"):
        persist.load(delta_path)
    # a full snapshot load_chains to an empty-overlay live store
    full = persist.load_chain(base_path)
    assert full.n_delta == 0 and full.n_tombstones == 0


def test_delta_snapshot_lineage_mismatch(tmp_path):
    live = _tiny_live()
    persist.save(live.base, str(tmp_path / "base.kgz"))
    live.insert([("<http://ex/new0>", "<http://ex/p1>", '"9"')])
    delta_path = str(tmp_path / "delta.kgz")
    persist.save_delta(live, delta_path, "base.kgz")
    # overwrite the parent with a different graph: the recorded parent
    # snapshot id no longer matches
    other = TripleStore.from_ntriples(
        [("<http://ex/sX>", "<http://ex/p0>", '"1"')]
    )
    persist.save(other, str(tmp_path / "base.kgz"))
    with pytest.raises(ValueError, match="snapshot id mismatch"):
        persist.load_chain(delta_path)


def test_save_delta_requires_saved_parent():
    live = _tiny_live()  # base never saved: no snapshot id
    live.insert([("<http://ex/new0>", "<http://ex/p1>", '"9"')])
    with pytest.raises(ValueError, match="snapshot id"):
        persist.save_delta(live, "/tmp/never-written.kgz", "base.kgz")


# --------------------------------------------------------------------------
# open_store cache: generation key (same-second same-size rewrite)
# --------------------------------------------------------------------------


def test_open_store_same_size_same_mtime_rewrite(tmp_path):
    """Compaction rewrites a .kgz in place; if the rewrite lands in the
    same mtime tick with the same byte size, the (mtime, size) cache key
    collides — the generation component must still force a reload."""
    path = str(tmp_path / "kg.kgz")
    a = TripleStore.from_ntriples([("<http://x/a>", "<http://x/p>", '"1"')])
    b = TripleStore.from_ntriples([("<http://x/b>", "<http://x/p>", '"1"')])
    persist.save(a, path, generation=0)
    st0 = os.stat(path)
    first = persist.open_store(path)
    assert first.decode_term(int(first.s[0])) == "<http://x/a>"
    persist.save(b, path, generation=1)
    # force the mtime collision the bug needs (FS mtime granularity can be
    # coarse enough to produce it naturally)
    os.utime(path, ns=(st0.st_atime_ns, st0.st_mtime_ns))
    st1 = os.stat(path)
    assert st1.st_size == st0.st_size  # premise: same-size rewrite
    assert st1.st_mtime_ns == st0.st_mtime_ns  # premise: same-tick rewrite
    second = persist.open_store(path)
    assert second is not first
    assert second.decode_term(int(second.s[0])) == "<http://x/b>"


# --------------------------------------------------------------------------
# edge semantics
# --------------------------------------------------------------------------


def test_empty_base_overlay():
    live = LiveStore(TripleStore.from_ntriples([]))
    assert live.n_triples == 0
    added = live.insert([("<http://ex/a>", "<http://ex/p>", '"1"')])
    assert added == 1 and live.n_triples == 1
    rows = live.solve("SELECT ?s ?o WHERE { ?s ?p ?o }").rows(0)
    assert rows == [("<http://ex/a>", '"1"')]
    live.delete([("<http://ex/a>", "<http://ex/p>", '"1"')])
    assert live.n_triples == 0
    assert live.solve("SELECT ?s ?o WHERE { ?s ?p ?o }").rows(0) == []
    compacted = live.compact()
    assert compacted.n_triples == 0


def test_tombstone_resurrect_and_dupes():
    live = _tiny_live()
    t = ("<http://ex/s0>", "<http://ex/p0>", '"1"')
    assert live.insert([t]) == 0  # already in base: no-op
    assert live.delete([t]) == (1, 1)  # tombstones the base row
    assert live.n_triples == 1 and live.n_tombstones == 1
    assert live.insert([t]) == 1  # resurrection clears the tombstone
    assert live.n_tombstones == 0 and live.n_triples == 2
    assert live.delete([("<http://ex/zz>", "<http://ex/p0>", '"1"')]) == (0, 0)
    # deleting a delta insert removes it from the log, no tombstone
    t2 = ("<http://ex/new1>", "<http://ex/p1>", '"5"')
    live.insert([t2])
    assert live.delete([t2]) == (1, 0)
    assert live.n_delta == 0


def test_kg_solve_on_live_store():
    """repro.kg.solve routes through the overlay when handed a LiveStore."""
    live = _tiny_live()
    live.insert([("<http://ex/s2>", "<http://ex/p0>", '"3"')])
    b = solve(live, parse_bgp("?s <http://ex/p0> ?o"))
    assert b.n == 3


# --------------------------------------------------------------------------
# the wire: live server round-trip, read-only rejection
# --------------------------------------------------------------------------


def test_server_live_roundtrip(tmp_path):
    reg = MetricsRegistry()
    kg_path = str(tmp_path / "srv.kgz")
    live = _tiny_live()
    persist.save(live.base, kg_path)
    srv = KGServer(
        live, port=0, log=False, registry=reg, kg_path=kg_path
    ).start()
    try:
        with connect(srv.host, srv.port) as c:
            q = "SELECT ?s ?o WHERE { ?s <http://ex/p0> ?o }"
            assert c.query(q)["n_total"] == 2
            r = c.insert([["<http://ex/new0>", "<http://ex/p0>", '"3"']])
            assert r["inserted"] == 1 and r["n_total"] == 3
            assert c.query(q)["n_total"] == 3
            r = c.delete([["<http://ex/s0>", "<http://ex/p0>", '"1"']])
            assert (r["deleted"], r["tombstoned"]) == (1, 1)
            assert c.query(q)["n_total"] == 2
            r = c.compact()
            assert r["compacted"] and r["persisted"] and r["n_total"] == 2
            assert c.query(q)["rows"] == [
                ["<http://ex/new0>", '"3"'],
                ["<http://ex/s1>", '"2"'],
            ]
            m = c.metrics()["metrics"]
            assert m["counters"]["live.inserts"] == 1
            assert m["counters"]["live.deletes"] == 1
            assert m["counters"]["live.tombstone_hits"] == 1
            assert m["counters"]["live.compactions"] == 1
            assert m["histograms"]["live.compact_ms"]["count"] == 1
            assert m["gauges"]["live.delta_fraction"] == 0.0
    finally:
        srv.stop()
    # compact persisted the rebuilt store under the served path
    reopened = persist.open_store(kg_path)
    assert reopened.n_triples == 2
    assert getattr(reopened, "_kgz_generation") == live.generation


def _raw_roundtrip(c, req: dict) -> dict:
    """Send on the client's socket without the error-raising wrapper, so
    the structured error reply itself can be inspected."""
    import json

    c._next_id += 1
    c._sock.sendall(
        (json.dumps({"id": c._next_id, **req}) + "\n").encode("utf-8")
    )
    return json.loads(c._rfile.readline())


def test_server_read_only_rejects_mutations():
    for store in (_tiny_live().base, _tiny_live()):  # plain and wrapped
        reg = MetricsRegistry()
        srv = KGServer(
            store, port=0, log=False, registry=reg, read_only=True
        ).start()
        try:
            with connect(srv.host, srv.port) as c:
                for req in (
                    {"op": "insert",
                     "triples": [["<http://x/a>", "<http://x/p>", '"1"']]},
                    {"op": "delete",
                     "triples": [["<http://x/a>", "<http://x/p>", '"1"']]},
                    {"op": "compact"},
                ):
                    resp = _raw_roundtrip(c, req)
                    assert resp["code"] == "read_only"
                    assert "read-only" in resp["error"]
                # queries still served after rejected writes
                assert (
                    c.query("SELECT ?s ?o WHERE { ?s <http://ex/p0> ?o }")[
                        "n_total"
                    ]
                    == 2
                )
            assert reg.counter("live.rejected").value == 3
        finally:
            srv.stop()
