"""Kernel-vs-oracle validation: shape/dtype sweeps + hypothesis properties.

Every Pallas kernel runs in interpret mode (CPU) and must agree with its
pure-jnp oracle in ``repro.kernels.ref`` exactly (integer outputs -> exact
equality, no tolerances needed).
"""

import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # test image without hypothesis: seeded-example fallback
    from _hypothesis_shim import given, settings, st

from repro.core import hashing, hashset
from repro.kernels import ops, ref
from repro.kernels.hash_mix import hash_mix
from repro.kernels.nested_join import nested_join


# ---------------------------------------------------------------- hash_mix


@pytest.mark.parametrize("n_words", [1, 2, 3, 5])
@pytest.mark.parametrize("n", [1, 7, 128, 4096, 5000])
def test_hash_mix_matches_oracle(n_words, n):
    rng = np.random.default_rng(n_words * 1000 + n)
    words = rng.integers(0, 2**31 - 1, size=(n_words, n)).astype(np.int32)
    hi_k, lo_k = hash_mix(jnp.asarray(words), salt=3)
    hi_r, lo_r = ref.hash_mix_ref([jnp.asarray(w) for w in words], salt=3)
    np.testing.assert_array_equal(np.asarray(hi_k), np.asarray(hi_r))
    np.testing.assert_array_equal(np.asarray(lo_k), np.asarray(lo_r))


# ------------------------------------------------------------ bucket_dedup


@pytest.mark.parametrize("n_parts", [1, 4, 8])
@pytest.mark.parametrize("n", [64, 1000, 4096])
@pytest.mark.parametrize("n_distinct", [16, 500])
def test_radix_dedup_semantics(n_parts, n, n_distinct):
    """The radix-partitioned kernel insert must classify new/duplicate keys
    exactly like a global exact set."""
    rng = np.random.default_rng(n * n_parts + n_distinct)
    vals = rng.integers(0, n_distinct, size=n).astype(np.int32)
    hi, lo = hashing.mix64([jnp.asarray(vals)])
    hi_np, lo_np = np.asarray(hi), np.asarray(lo)
    valid = rng.random(n) > 0.1

    table = ops.make_radix_table(4 * n, n_parts)
    half = n // 2
    seen: set = set()
    expected = []
    for h, l, v in zip(hi_np.tolist(), lo_np.tolist(), valid.tolist()):
        if not v:
            expected.append(False)
            continue
        expected.append((h, l) not in seen)
        seen.add((h, l))

    got = []
    for sl in (slice(0, half), slice(half, n)):
        table, is_new, ovf = ops.radix_dedup_insert(
            table,
            jnp.asarray(hi_np[sl]),
            jnp.asarray(lo_np[sl]),
            jnp.asarray(valid[sl]),
        )
        assert not bool(ovf)
        got.extend(np.asarray(is_new).tolist())
    assert got == expected


def test_bucket_dedup_kernel_matches_ref_oracle():
    """Direct kernel vs ref.bucket_dedup_ref on identical partitioned input."""
    from repro.kernels.bucket_dedup import bucket_dedup

    rng = np.random.default_rng(0)
    n_parts, part_len, cap = 4, 256, 1024
    vals = rng.integers(0, 300, size=(n_parts, part_len)).astype(np.int32)
    hi, lo = hashing.mix64([jnp.asarray(vals.reshape(-1))])
    khi = jnp.asarray(np.asarray(hi).reshape(n_parts, part_len))
    klo = jnp.asarray(np.asarray(lo).reshape(n_parts, part_len))
    valid = jnp.asarray(rng.random((n_parts, part_len)) > 0.2)
    thi = jnp.full((n_parts, cap), hashing.EMPTY, jnp.uint32)
    tlo = jnp.full((n_parts, cap), hashing.EMPTY, jnp.uint32)

    k_thi, k_tlo, k_new, k_ovf = bucket_dedup(khi, klo, valid, thi, tlo)
    r_thi, r_tlo, r_new = ref.bucket_dedup_ref(khi, klo, thi, tlo, valid)
    np.testing.assert_array_equal(np.asarray(k_thi), np.asarray(r_thi))
    np.testing.assert_array_equal(np.asarray(k_tlo), np.asarray(r_tlo))
    np.testing.assert_array_equal(np.asarray(k_new), np.asarray(r_new))
    assert not bool(np.any(np.asarray(k_ovf)))


# ------------------------------------------------------------- nested_join


@pytest.mark.parametrize("m,n", [(10, 10), (300, 100), (1000, 2000), (257, 1025)])
@pytest.mark.parametrize("n_keys", [5, 50])
def test_nested_join_matches_oracle(m, n, n_keys):
    rng = np.random.default_rng(m + n + n_keys)
    pk = rng.integers(0, n_keys, size=n).astype(np.int32)
    ps = rng.integers(0, 10**6, size=n).astype(np.int32)
    ck = rng.integers(0, n_keys + 3, size=m).astype(np.int32)
    K = int(max((np.bincount(pk, minlength=n_keys)).max(), 1))

    subj_k, valid_k, trunc_k = nested_join(
        jnp.asarray(pk), jnp.asarray(ps), jnp.asarray(ck), K,
        block_m=64, block_n=128,
    )
    subj_r, valid_r = ref.nested_join_ref(
        jnp.asarray(pk), jnp.asarray(ps), jnp.asarray(ck), K
    )
    np.testing.assert_array_equal(np.asarray(valid_k), np.asarray(valid_r))
    np.testing.assert_array_equal(
        np.asarray(subj_k)[np.asarray(valid_k)], np.asarray(subj_r)[np.asarray(valid_r)]
    )
    assert not bool(trunc_k)


def test_nested_join_truncation_flag():
    pk = jnp.zeros(64, jnp.int32)          # all the same key
    ps = jnp.arange(64, dtype=jnp.int32)
    ck = jnp.zeros(4, jnp.int32)
    _, _, trunc = nested_join(pk, ps, ck, max_matches=8, block_m=8, block_n=16)
    assert bool(trunc)


# ------------------------------------------------------- hypothesis sweeps


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 600),
    n_distinct=st.integers(1, 64),
    n_parts=st.sampled_from([1, 2, 8]),
    seed=st.integers(0, 2**16),
)
def test_radix_dedup_property(n, n_distinct, n_parts, seed):
    """Property: sum(is_new) == |distinct valid keys| and every duplicate is
    flagged False, for arbitrary shapes and duplicate structures."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, n_distinct, size=n).astype(np.int32)
    hi, lo = hashing.mix64([jnp.asarray(vals)])
    table = ops.make_radix_table(4 * n + 64, n_parts)
    table, is_new, ovf = ops.radix_dedup_insert(
        table, hi, lo, jnp.ones(n, dtype=bool)
    )
    assert not bool(ovf)
    assert int(np.asarray(is_new).sum()) == len(set(vals.tolist()))


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 200),
    n=st.integers(1, 200),
    n_keys=st.integers(1, 30),
    seed=st.integers(0, 2**16),
)
def test_nested_join_property(m, n, n_keys, seed):
    """Property: kernel join result == brute-force python join (as multisets
    per row, in parent order)."""
    rng = np.random.default_rng(seed)
    pk = rng.integers(0, n_keys, size=n).astype(np.int32)
    ps = rng.integers(0, 1000, size=n).astype(np.int32)
    ck = rng.integers(0, n_keys, size=m).astype(np.int32)
    K = int(max(np.bincount(pk, minlength=n_keys).max(), 1))
    subj, valid, trunc = nested_join(
        jnp.asarray(pk), jnp.asarray(ps), jnp.asarray(ck), K,
        block_m=32, block_n=64,
    )
    assert not bool(trunc)
    subj, valid = np.asarray(subj), np.asarray(valid)
    for i in range(m):
        want = [s for k, s in zip(pk.tolist(), ps.tolist()) if k == ck[i]]
        got = subj[i][valid[i]].tolist()
        assert got == want
