"""Minimal stand-in for ``hypothesis`` when it isn't installed.

The test image doesn't always ship hypothesis; property tests then fall back
to this shim, which draws a fixed number of seeded pseudo-random examples per
test instead of skipping the whole module.  Only the tiny API surface the
test-suite uses is provided: ``given`` (kwargs form), ``settings``
(``max_examples``/``deadline``), ``st.integers`` and ``st.sampled_from``.
"""

from __future__ import annotations


import types

import numpy as np

_DEFAULT_EXAMPLES = 20
_SEED = 0x5EED


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(options) -> _Strategy:
    opts = list(options)
    return _Strategy(lambda rng: opts[int(rng.integers(0, len(opts)))])


def given(**strategies):
    def decorate(fn):
        # NB: deliberately no functools.wraps — pytest must see a zero-arg
        # signature, not the strategy parameters (it would treat them as
        # fixtures).
        def run():
            rng = np.random.default_rng(_SEED)
            for _ in range(getattr(run, "_max_examples", _DEFAULT_EXAMPLES)):
                fn(**{k: s.draw(rng) for k, s in strategies.items()})

        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run._max_examples = _DEFAULT_EXAMPLES
        return run

    return decorate


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    def decorate(fn):
        fn._max_examples = max_examples
        return fn

    return decorate


st = types.SimpleNamespace(integers=_integers, sampled_from=_sampled_from)
