"""repro.shard: subject-hash partitioning, dispatch-mode routing, the
scatter/gather merge vs the unsharded engine (property tests across shard
counts), manifest persistence, sharded ingestion, the coordinator server,
and the satellite regressions (open_store LRU cap, signature-legend cap)."""

import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # test image without hypothesis: seeded-example fallback
    from _hypothesis_shim import given, settings, st

from repro import api
from repro.api import LocalSession
from repro.kg import persist
from repro.kg.store import TripleStore
from repro.obs import MetricsRegistry
from repro.serve.algebra import parse_select, to_text
from repro.shard import (
    build_shard_stores,
    choose_dispatch,
    ingest_sharded,
    partition_store,
    partition_triples,
    shard_of_term,
    shard_store,
)
from repro.shard import merge as M
from repro.shard.coordinator import ShardGroup, ShardSession, _LocalBackend


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

SUBS = [f"<http://ex/s{i}>" for i in range(5)]
PREDS = [f"<http://ex/p{i}>" for i in range(3)]
LITS = ['"1"', '"2"', '"10"', '"2.5"', '"-3"', '"abc"', '"b c"', '""']
OBJS = SUBS[:2] + LITS


def rand_store(seed: int, n_triples: int) -> TripleStore:
    rng = np.random.default_rng(seed)
    triples = {
        (
            SUBS[rng.integers(0, len(SUBS))],
            PREDS[rng.integers(0, len(PREDS))],
            OBJS[rng.integers(0, len(OBJS))],
        )
        for _ in range(n_triples)
    }
    return TripleStore.from_ntriples(sorted(triples))


def decoded_triples(store: TripleStore):
    return [
        (
            store.decode_term(int(store.s[i])),
            store.decode_term(int(store.p[i])),
            store.decode_term(int(store.o[i])),
        )
        for i in range(store.n_triples)
    ]


def sharded_session(store: TripleStore, n_shards: int) -> ShardSession:
    """In-process scatter/gather session over n partitions of ``store``,
    with a private registry so counter asserts see only their own run."""
    backends = [
        _LocalBackend(LocalSession(s))
        for s in build_shard_stores(store, n_shards)
    ]
    return ShardSession(ShardGroup(backends, registry=MetricsRegistry()))


def assert_parity(store: TripleStore, qtext: str, n_shards: int) -> None:
    want = LocalSession(store).query(qtext)
    sess = sharded_session(store, n_shards)
    try:
        got = sess.query(qtext)
    finally:
        sess.close()
    assert got.vars == want.vars, (qtext, got.vars, want.vars)
    assert got.rows == want.rows, (
        f"{qtext} @ {n_shards} shards\n got: {got.rows[:5]}"
        f"\nwant: {want.rows[:5]}"
    )
    assert got.n_total == want.n_total, (qtext, got.n_total, want.n_total)
    assert got.agg_vars == want.agg_vars, qtext


# the eight algebra template classes the sharded engine must answer
# byte-identically: every dispatch mode (routed / scatter / decompose) and
# every merge rule (plain, ORDER BY/LIMIT top-k, DISTINCT dedup, keyed and
# global aggregate re-sum, OPTIONAL nulls, UNION bags) is covered
TEMPLATES = [
    lambda p, s: f"SELECT * WHERE {{ ?a {p[0]} ?b }}",
    lambda p, s: f"SELECT ?b WHERE {{ {s} {p[0]} ?b }}",  # routed
    lambda p, s: (  # star BGP + LIMIT: scatter with top-k merge
        f"SELECT * WHERE {{ ?a {p[0]} ?b . ?a {p[1]} ?c }} LIMIT 4"
    ),
    lambda p, s: (  # subject-object chain: decomposed dispatch
        f"SELECT * WHERE {{ ?a {p[0]} ?b . ?b {p[1]} ?c }}"
    ),
    lambda p, s: (
        f"SELECT DISTINCT ?b WHERE {{ ?a {p[0]} ?b }} ORDER BY ?b LIMIT 3"
    ),
    lambda p, s: (
        f"SELECT ?b (COUNT(?a) AS ?n) WHERE {{ ?a {p[0]} ?b }} "
        "GROUP BY ?b ORDER BY DESC(?n) LIMIT 5"
    ),
    lambda p, s: f"SELECT (COUNT(*) AS ?n) WHERE {{ ?a {p[0]} ?b }}",
    lambda p, s: (
        f"SELECT * WHERE {{ ?a {p[0]} ?b OPTIONAL {{ ?a {p[1]} ?c }} "
        f'FILTER(?b != "zz") }}'
    ),
]


# --------------------------------------------------------------------------
# partitioning
# --------------------------------------------------------------------------


def test_shard_of_term_stable_and_bounded():
    # crc32 is pinned by the manifest spec: same subject -> same shard,
    # everywhere, forever; single shard degenerates to 0
    for s in SUBS:
        assert shard_of_term(s, 1) == 0
        for n in (2, 3, 4, 7):
            a, b = shard_of_term(s, n), shard_of_term(s, n)
            assert a == b and 0 <= a < n
    import zlib

    assert shard_of_term("<http://ex/s0>", 4) == (
        zlib.crc32(b"<http://ex/s0>") % 4
    )


def test_partition_covers_and_colocates():
    store = rand_store(7, 60)
    triples = decoded_triples(store)
    for n in (1, 2, 4):
        buckets = partition_triples(triples, n)
        assert sum(len(b) for b in buckets) == len(triples)
        assert sorted(t for b in buckets for t in b) == sorted(triples)
        for i, bucket in enumerate(buckets):
            assert all(shard_of_term(s, n) == i for s, _p, _o in bucket)
        # the store-level partition agrees with the triple-level one
        assert [sorted(b) for b in partition_store(store, n)] == [
            sorted(b) for b in buckets
        ]
    stores = build_shard_stores(store, 4)
    assert sum(s.n_triples for s in stores) == store.n_triples


# --------------------------------------------------------------------------
# dispatch-mode routing
# --------------------------------------------------------------------------


def test_choose_dispatch_modes():
    p0, p1 = PREDS[0], PREDS[1]
    routed = parse_select(f"SELECT ?o WHERE {{ {SUBS[0]} {p0} ?o }}")
    star = parse_select(f"?a {p0} ?b . ?a {p1} ?c")
    chain = parse_select(f"?a {p0} ?b . ?b {p1} ?c")
    assert choose_dispatch(routed, 4) == (
        M.ROUTED, shard_of_term(SUBS[0], 4)
    )
    assert choose_dispatch(star, 4) == (M.SCATTER, None)
    assert choose_dispatch(chain, 4) == (M.DECOMPOSE, None)
    # one shard never fans out, whatever the shape
    for q in (routed, star, chain):
        assert choose_dispatch(q, 1) == (M.ROUTED, 0)


def test_scatter_query_strips_order_limit_for_aggregates_only():
    agg = parse_select(
        f"SELECT ?b (COUNT(?a) AS ?n) WHERE {{ ?a {PREDS[0]} ?b }} "
        "GROUP BY ?b ORDER BY DESC(?n) LIMIT 2"
    )
    sub = M.scatter_query(agg)
    assert sub.order_by == () and sub.limit is None
    plain = parse_select(f"SELECT ?b WHERE {{ ?a {PREDS[0]} ?b }} LIMIT 2")
    assert M.scatter_query(plain) is plain
    # decode caps: aggregates need every partial group, DISTINCT needs the
    # full per-shard distinct set, plain rows only the reply cap
    assert M.scatter_decode_limit(agg, 10) == M.BIG_LIMIT
    dist = parse_select(
        f"SELECT DISTINCT ?b WHERE {{ ?a {PREDS[0]} ?b }} LIMIT 3"
    )
    assert M.scatter_decode_limit(dist, 10) == 3
    assert M.scatter_decode_limit(plain, 10) == 10


def test_merge_scatter_rules():
    plain = parse_select(f"SELECT ?b WHERE {{ ?a {PREDS[0]} ?b }} LIMIT 3")
    rows, n = M.merge_scatter(
        plain, [([('"b"',), ('"a"',)], 2), ([('"c"',), ('"0"',)], 5)]
    )
    assert rows == [('"0"',), ('"a"',), ('"b"',)] and n == 3  # min(7, LIMIT)
    agg = parse_select(
        f"SELECT ?b (COUNT(?a) AS ?n) WHERE {{ ?a {PREDS[0]} ?b }} GROUP BY ?b"
    )
    rows, n = M.merge_scatter(
        agg, [([('"x"', 2), ('"y"', 1)], 2), ([('"x"', 3)], 1)]
    )
    assert rows == [('"x"', 5), ('"y"', 1)] and n == 2  # partials re-summed
    dist = parse_select(f"SELECT DISTINCT ?b WHERE {{ ?a {PREDS[0]} ?b }}")
    rows, n = M.merge_scatter(
        dist, [([('"a"',), ('"b"',)], 2), ([('"b"',), ('"c"',)], 2)]
    )
    assert rows == [('"a"',), ('"b"',), ('"c"',)] and n == 3  # cross-shard dedup


def test_decomposed_to_text_roundtrip():
    chain = parse_select(f"?a {PREDS[0]} ?b . ?b {PREDS[1]} ?c")
    for sub, _subject in M.decompose_queries(chain):
        again = parse_select(to_text(sub))
        assert again.patterns == sub.patterns
        assert again.out_vars() == sub.out_vars()


# --------------------------------------------------------------------------
# sharded answers == unsharded answers (the core property)
# --------------------------------------------------------------------------


def test_all_templates_all_shard_counts():
    store = rand_store(13, 40)
    for n in (1, 2, 4):
        for tpl in TEMPLATES:
            assert_parity(store, tpl(PREDS, SUBS[0]), n)


def test_empty_store_parity():
    store = TripleStore.from_ntriples([])
    for tpl in TEMPLATES:
        assert_parity(store, tpl(PREDS, SUBS[0]), 2)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 10**6),
    n=st.integers(0, 30),
    t=st.integers(0, len(TEMPLATES) - 1),
    shards=st.sampled_from([1, 2, 4]),
)
def test_sharded_matches_unsharded_on_random_graphs(seed, n, t, shards):
    rng = np.random.default_rng(seed + 1)
    store = rand_store(seed, n)
    p = [PREDS[rng.integers(0, len(PREDS))] for _ in range(2)]
    s = SUBS[rng.integers(0, len(SUBS))]
    assert_parity(store, TEMPLATES[t](p, s), shards)


def test_routed_query_touches_exactly_one_shard():
    store = rand_store(5, 50)
    sess = sharded_session(store, 4)
    reg = sess.group.registry
    try:
        sess.query(f"SELECT ?o WHERE {{ {SUBS[0]} {PREDS[0]} ?o }}")
        assert reg.counter("shard.routed").value == 1
        assert reg.counter("shard.shard_requests").value == 1
        assert reg.histogram("shard.fanout").max == 1.0
        sess.query(f"?a {PREDS[0]} ?b . ?a {PREDS[1]} ?c")
        assert reg.counter("shard.scattered").value == 1
        assert reg.counter("shard.shard_requests").value == 1 + 4
        assert reg.histogram("shard.fanout").max == 4.0
    finally:
        sess.close()


# --------------------------------------------------------------------------
# manifest persistence + sharded ingestion
# --------------------------------------------------------------------------


def test_manifest_roundtrip_and_validation(tmp_path):
    store = rand_store(21, 30)
    path = str(tmp_path / "kg.shards.json")
    manifest = ingest_sharded(decoded_triples(store), path, 2)
    assert persist.is_manifest(path)
    m = persist.load_manifest(path)
    assert m["format"] == persist.MANIFEST_FORMAT and m["n_shards"] == 2
    assert m["partition"] == {"by": "subject", "hash": "crc32"}
    assert m["dictionary"]["n_triples"] == store.n_triples
    # shard term dictionaries overlap, so their sum bounds the union
    assert m["dictionary"]["n_terms_shards"] >= m["dictionary"]["n_terms_union"]
    for entry in m["shards"]:
        assert os.path.exists(entry["abs_path"])
        assert persist.open_store(entry["abs_path"]).n_triples == (
            entry["n_triples"]
        )
    assert sum(e["n_triples"] for e in m["shards"]) == store.n_triples
    assert manifest["n_shards"] == 2

    bad = dict(m, format="nonsense/9")
    with pytest.raises(ValueError, match="format"):
        persist.save_manifest(str(tmp_path / "bad.json"), bad)
    mm = {k: v for k, v in m.items()}
    mm["n_shards"] = 3  # disagrees with the 2 shard entries
    p2 = str(tmp_path / "bad2.json")
    with open(p2, "w", encoding="utf-8") as f:
        json.dump(
            {**mm, "shards": [{"path": e["path"]} for e in m["shards"]]}, f
        )
    with pytest.raises(ValueError, match="n_shards"):
        persist.load_manifest(p2)
    p3 = str(tmp_path / "bad3.json")
    with open(p3, "w", encoding="utf-8") as f:
        json.dump({**mm, "n_shards": 2, "partition": {"by": "object"}}, f)
    with pytest.raises(ValueError, match="partition"):
        persist.load_manifest(p3)
    # the sniff rejects non-manifest files without raising
    assert not persist.is_manifest(str(tmp_path / "missing.json"))
    other = str(tmp_path / "plain.json")
    with open(other, "w", encoding="utf-8") as f:
        json.dump({"hello": 1}, f)
    assert not persist.is_manifest(other)


def test_multiprocess_ingest_matches_serial(tmp_path):
    store = rand_store(31, 40)
    triples = decoded_triples(store)
    serial = str(tmp_path / "a.shards.json")
    parallel = str(tmp_path / "b.shards.json")
    ingest_sharded(triples, serial, 2, workers=0)
    ingest_sharded(triples, parallel, 2, workers=2)  # spawned pool
    ms, mp = persist.load_manifest(serial), persist.load_manifest(parallel)
    for es, ep in zip(ms["shards"], mp["shards"]):
        assert es["n_triples"] == ep["n_triples"]
        assert es["n_terms"] == ep["n_terms"]
        a = persist.open_store(es["abs_path"])
        b = persist.open_store(ep["abs_path"])
        assert decoded_triples(a) == decoded_triples(b)


# --------------------------------------------------------------------------
# api.connect over a manifest (queries + routed mutations)
# --------------------------------------------------------------------------


def test_connect_manifest_parity_and_mutations(tmp_path):
    store = rand_store(17, 50)
    path = str(tmp_path / "kg.shards.json")
    shard_store(store, path, 2)
    single = LocalSession(store)
    with api.connect(path) as sess:
        assert isinstance(sess, ShardSession)
        for tpl in TEMPLATES:
            q = tpl(PREDS, SUBS[0])
            a, b = single.query(q), sess.query(q)
            assert (a.rows, a.n_total) == (b.rows, b.n_total), q
        # inserts route by subject hash: one triple -> one shard
        r = sess.insert([("<http://ex/new>", PREDS[0], '"fresh"')])
        assert r["inserted"] == 1 and r["shards_touched"] == 1
        got = sess.query(f"SELECT ?o WHERE {{ <http://ex/new> {PREDS[0]} ?o }}")
        assert got.rows == [('"fresh"',)]
        d = sess.delete([("<http://ex/new>", PREDS[0], '"fresh"')])
        assert d["deleted"] == 1 and d["shards_touched"] == 1
        # compact broadcasts to every shard
        c = sess.compact()
        assert c["compacted"] and c["shards_touched"] == 2
        with pytest.raises(api.QueryParseError):
            sess.query("SELECT nonsense {")


def test_connect_manifest_read_only(tmp_path):
    store = rand_store(19, 20)
    path = str(tmp_path / "ro.shards.json")
    shard_store(store, path, 2)
    with api.connect(path, read_only=True) as sess:
        assert sess.query(f"?a {PREDS[0]} ?b").n_total >= 0
        with pytest.raises(api.ReadOnlyError):
            sess.insert([("<http://ex/x>", PREDS[0], '"v"')])


# --------------------------------------------------------------------------
# the coordinator server (wire protocol over a shard group)
# --------------------------------------------------------------------------


def test_coordinator_server_end_to_end(tmp_path):
    from repro.serve.client import connect
    from repro.shard.coordinator import Coordinator

    store = rand_store(23, 60)
    path = str(tmp_path / "kg.shards.json")
    shard_store(store, path, 2)
    reg = MetricsRegistry()
    coord = Coordinator.from_manifest(
        path, port=0, wire_shards=False, registry=reg, log=False,
        linger_ms=1.0,
    ).start()
    single = LocalSession(store)
    try:
        with connect("127.0.0.1", coord.port, retry_s=5.0) as c:
            for tpl in TEMPLATES:
                qt = tpl(PREDS, SUBS[0])
                want = single.query(qt)
                r = c.query(qt)
                assert [tuple(x) for x in r["rows"]] == want.rows, qt
                assert r["n_total"] == want.n_total, qt
            routed0 = reg.counter("shard.routed").value
            reqs0 = reg.counter("shard.shard_requests").value
            c.query(f"SELECT ?o WHERE {{ {SUBS[1]} {PREDS[0]} ?o }}")
            assert reg.counter("shard.routed").value == routed0 + 1
            assert reg.counter("shard.shard_requests").value == reqs0 + 1
            # mutations apply through the coordinator barrier
            r = c.insert([["<http://ex/wire>", PREDS[0], '"w"']])
            assert r["inserted"] == 1 and r["shards_touched"] == 1
            got = c.query(f"SELECT ?o WHERE {{ <http://ex/wire> {PREDS[0]} ?o }}")
            assert [tuple(x) for x in got["rows"]] == [('"w"',)]
            # the metrics op reports group counters and signature examples
            met = c.metrics()
            assert met["metrics"]["counters"]["shard.scattered"] >= 1
            assert met["metrics"]["gauges"]["shard.n_shards"] == 2
    finally:
        coord.stop()


def test_coordinator_wire_shards_spawns_real_servers(tmp_path):
    from repro.serve.client import connect
    from repro.shard.coordinator import Coordinator

    store = rand_store(29, 30)
    path = str(tmp_path / "kg.shards.json")
    shard_store(store, path, 2)
    coord = Coordinator.from_manifest(
        path, port=0, wire_shards=True, registry=MetricsRegistry(),
        log=False, linger_ms=1.0,
    ).start()
    single = LocalSession(store)
    try:
        assert len(coord._servers) == 2
        with connect("127.0.0.1", coord.port, retry_s=5.0) as c:
            qt = f"SELECT * WHERE {{ ?a {PREDS[0]} ?b }}"
            want = single.query(qt)
            r = c.query(qt)
            assert [tuple(x) for x in r["rows"]] == want.rows
            assert r["n_total"] == want.n_total
    finally:
        coord.stop()


# --------------------------------------------------------------------------
# satellite regressions
# --------------------------------------------------------------------------


def test_open_store_cache_lru_cap(tmp_path):
    _, cap0 = persist.open_store_cache_info()
    try:
        persist.set_open_store_cache_size(2)
        paths = []
        for i in range(4):
            p = str(tmp_path / f"s{i}.kgz")
            persist.save(rand_store(i, 5 + i), p)
            paths.append(p)
        for p in paths:
            persist.open_store(p)
            size, cap = persist.open_store_cache_info()
            assert size <= cap == 2
        # most-recent stays resident: reopening it is the cached object
        again = persist.open_store(paths[-1])
        assert again is persist.open_store(paths[-1])
        with pytest.raises(ValueError):
            persist.set_open_store_cache_size(0)
    finally:
        persist.set_open_store_cache_size(cap0)


def test_sig_legend_capped():
    from repro.serve.server import MAX_TRACKED_SIGS, track_sig

    examples: dict = {}
    for i in range(MAX_TRACKED_SIGS):
        assert track_sig(examples, f"sig{i}", f"q{i}") == f"sig{i}"
    assert len(examples) == MAX_TRACKED_SIGS
    # the legend is full: new signatures collapse into one overflow label
    assert track_sig(examples, "sig-new", "q-new") == "overflow"
    assert len(examples) == MAX_TRACKED_SIGS
    assert "sig-new" not in examples
    # known labels keep reporting under their own name
    assert track_sig(examples, "sig0", "q0-again") == "sig0"
