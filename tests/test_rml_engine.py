"""RML layer end-to-end: parser round-trip, generator statistics, and the
engine-equivalence guarantee (optimized == naive output) on all testbeds."""

import numpy as np
import pytest

from repro.core.executor import create_kg
from repro.rml import generator, parser, serializer


@pytest.mark.parametrize("kind", ["SOM", "ORM", "OJM"])
@pytest.mark.parametrize("dup", [0.25, 0.75])
def test_engines_produce_identical_kg(kind, dup):
    tb = generator.make_testbed(kind, 1500, dup, n_poms=2, seed=11)
    tables = {"csv:child.csv": tb.child}
    if tb.parent is not None:
        tables["csv:parent.csv"] = tb.parent
    results = {
        name: create_kg(tb.doc, tables=tables, engine=eng, join_strategy=js)
        for name, (eng, js) in {
            "opt-sorted": ("optimized", "sorted"),
            "opt-hash": ("optimized", "hash"),
            "naive": ("naive", "sorted"),
        }.items()
    }
    sets = {k: r.as_set() for k, r in results.items()}
    assert sets["opt-sorted"] == sets["opt-hash"] == sets["naive"]
    assert results["opt-sorted"].n_triples > 0


@pytest.mark.parametrize("kind", ["SOM", "ORM", "OJM"])
def test_parser_roundtrip(kind):
    tb = generator.make_testbed(kind, 100, 0.25, n_poms=3)
    ttl = serializer.to_turtle(tb.doc)
    doc2 = parser.parse(ttl)
    assert doc2.triples_maps == tb.doc.triples_maps


def test_parse_from_file_and_run(tmp_path):
    tb = generator.make_testbed("OJM", 400, 0.25, n_poms=1)
    tb.write(str(tmp_path))
    serializer.write_turtle(tb.doc, str(tmp_path / "map.ttl"))
    doc = parser.parse_file(str(tmp_path / "map.ttl"))
    res = create_kg(doc, data_root=str(tmp_path))
    assert res.n_triples > 0
    out = tmp_path / "kg.nt"
    n = res.write_ntriples(str(out))
    assert n == res.n_triples
    lines = out.read_text().splitlines()
    assert all(line.endswith(" .") and line.count(" ") >= 3 for line in lines)


def test_generator_duplicate_rate():
    """The testbed construction: dup_rate of rows are duplicates, each
    duplicated value repeated DUP_GROUP times (paper §V)."""
    t = generator.make_child_table(10000, 0.75, seed=3)
    ids = t["MUTATION_ID"]
    _, counts = np.unique(ids, return_counts=True)
    n_dup_rows = int((counts[counts > 1]).sum())
    assert n_dup_rows / len(ids) == pytest.approx(0.75, abs=0.02)
    # duplicated values repeat ~DUP_GROUP times
    assert np.median(counts[counts > 1]) == pytest.approx(generator.DUP_GROUP, abs=2)


def test_duplicate_rate_affects_unique_counts():
    """Q1 of the paper: duplicate rate drives |S_p| and therefore φ."""
    out = {}
    for dup in (0.25, 0.75):
        tb = generator.make_testbed("SOM", 4000, dup, n_poms=1, seed=5)
        res = create_kg(tb.doc, tables={"csv:child.csv": tb.child})
        st = [s for s in res.stats.values() if s.kind == "SOM"][0]
        out[dup] = st.n_unique / st.n_candidates
    assert out[0.75] < out[0.25] < 1.0


def test_pjtt_reuse_across_rules():
    """A parent map referenced by several join rules builds ONE PJTT."""
    from repro.core import planner

    tb = generator.make_ojm_testbed(200, 0.25, n_poms=3)
    # same parent column join: collapse the three ExonMaps into joins
    # against one map to exercise reuse
    from repro.rml.model import (
        JoinCondition, MappingDocument, PredicateObjectMap, RefObjectMap,
    )

    base = tb.doc.triples_maps["TriplesMap1"]
    parent = tb.doc.triples_maps["ExonMap1"]
    poms = tuple(
        PredicateObjectMap(
            predicate=f"http://repro.org/vocab/p{i}",
            object_map=RefObjectMap(
                parent_triples_map="ExonMap1",
                join=JoinCondition("ACCESSION_NUMBER", "ACCESSION_NUMBER"),
            ),
        )
        for i in range(3)
    )
    import dataclasses

    doc = MappingDocument(
        {
            "TriplesMap1": dataclasses.replace(base, poms=poms),
            "ExonMap1": parent,
        }
    )
    plan = planner.plan(doc)
    assert len(plan.pjtt_builds) == 1  # one build, three consumers
    assert sum(1 for op in plan.ops if op.kind == "OJM") == 3
