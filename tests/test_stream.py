"""repro.stream: Block/Dataset semantics, chunked readers, glob sharding,
and the streamed-engine equivalence guarantee (stream == optimized == naive).
"""

import json
import os

import numpy as np
import pytest

from repro.core.executor import create_kg
from repro.data import pipeline
from repro.data.sources import load_json
from repro.rml import generator
from repro.stream import Dataset, read_csv, read_json, read_source
from repro.stream.block import Block


def _write(path, text):
    with open(path, "w", encoding="utf-8") as f:
        f.write(text)


# ------------------------------------------------------------- block basics


def test_block_select_fills_missing_columns():
    b = Block({"A": np.array(["1", "2"], object)})
    s = b.select(("A", "B"))
    assert list(s.columns["B"]) == ["", ""]
    assert s.schema == ("A", "B")


def test_block_from_records_unions_keys():
    b = Block.from_records([{"a": 1}, {"b": 2}, {"a": 3, "b": 4}])
    assert sorted(b.schema) == ["a", "b"]
    assert list(b.columns["a"]) == ["1", "", "3"]
    assert list(b.columns["b"]) == ["", "2", "4"]


# ------------------------------------------------- block-boundary coverage


def test_empty_csv_source(tmp_path):
    _write(tmp_path / "e.csv", "A,B\n")
    ds = read_csv(str(tmp_path / "e.csv"), block_rows=4)
    assert ds.count() == 0
    assert list(ds.iter_blocks()) == []


def test_headerless_empty_file(tmp_path):
    _write(tmp_path / "none.csv", "")
    assert read_csv(str(tmp_path / "none.csv")).count() == 0


@pytest.mark.parametrize("n,block_rows", [(3, 8), (8, 8), (16, 8), (17, 8), (1, 1)])
def test_csv_block_sizes(tmp_path, n, block_rows):
    """Single short block, exact multiples, and a one-row tail."""
    _write(tmp_path / "t.csv", "A\n" + "".join(f"{i}\n" for i in range(n)))
    blocks = list(read_csv(str(tmp_path / "t.csv"), block_rows=block_rows).iter_blocks())
    assert sum(b.n_rows for b in blocks) == n
    assert all(b.n_rows == block_rows for b in blocks[:-1])
    assert 0 < blocks[-1].n_rows <= block_rows
    got = np.concatenate([b.columns["A"] for b in blocks])
    assert list(got) == [str(i) for i in range(n)]


def test_rebatch_across_source_chunks():
    t = {"x": np.arange(25).astype(str).astype(object)}
    sizes = [
        b.n_rows
        for b in Dataset.from_table(t, block_rows=10).batch(4).iter_blocks()
    ]
    assert sizes == [4, 4, 4, 4, 4, 4, 1]


def test_padded_tail_validity_mask(tmp_path):
    """The engine pads the final short block; the mask must cover exactly the
    real rows and reconstruction must round-trip."""
    _write(tmp_path / "t.csv", "A\n" + "".join(f"{i}\n" for i in range(10)))
    ds = read_csv(str(tmp_path / "t.csv"), block_rows=4)
    recon = []
    for block in ds.iter_blocks():
        for batch in pipeline.batches(block.columns, 4):
            assert len(batch.arrays["A"]) == 4  # fixed jit shape
            assert batch.valid.sum() == block.n_rows
            recon.extend(batch.arrays["A"][batch.valid].tolist())
    assert recon == [str(i) for i in range(10)]


def test_take_and_materialize(tmp_path):
    _write(tmp_path / "t.csv", "A\n" + "".join(f"{i}\n" for i in range(9)))
    ds = read_csv(str(tmp_path / "t.csv"), block_rows=2)
    assert ds.take(4).n_rows == 4
    assert ds.materialize().n_rows == 9
    assert ds.schema() == ("A",)


# ------------------------------------------------------------ json reading


def test_json_lines_streamed(tmp_path):
    recs = [{"a": str(i), "b": str(i % 3)} for i in range(11)]
    _write(tmp_path / "t.jsonl", "".join(json.dumps(r) + "\n" for r in recs))
    blocks = list(read_json(str(tmp_path / "t.jsonl"), block_rows=4).iter_blocks())
    assert [b.n_rows for b in blocks] == [4, 4, 3]
    assert list(np.concatenate([b.columns["a"] for b in blocks])) == [
        str(i) for i in range(11)
    ]


def test_json_iterator_expansion(tmp_path):
    recs = [{"items": [{"v": "1"}, {"v": "2"}]}, {"items": [{"v": "3"}]}]
    _write(tmp_path / "t.jsonl", "".join(json.dumps(r) + "\n" for r in recs))
    ds = read_json(str(tmp_path / "t.jsonl"), block_rows=2, iterator="$.items")
    assert list(ds.materialize().columns["v"]) == ["1", "2", "3"]


def test_json_heterogeneous_keys_stream_and_eager_agree(tmp_path):
    """Records with extra/missing fields: the eager loader must union keys
    (the records[0]-only bug) and the streamed reader must match it."""
    recs = [{"a": "1"}, {"a": "2", "b": "x"}, {"b": "y", "c": "z"}]
    _write(tmp_path / "t.jsonl", "".join(json.dumps(r) + "\n" for r in recs))
    eager = load_json(str(tmp_path / "t.jsonl"))
    assert sorted(eager) == ["a", "b", "c"]
    assert list(eager["b"]) == ["", "x", "y"]
    assert list(eager["c"]) == ["", "", "z"]
    streamed = (
        read_json(str(tmp_path / "t.jsonl"), block_rows=2)
        .project("a", "b", "c")
        .materialize()
    )
    for k in ("a", "b", "c"):
        assert list(streamed.columns[k]) == list(eager[k])


# ------------------------------------------------------------ glob sharding


def test_glob_multi_file_sharding(tmp_path):
    for i in range(3):
        _write(tmp_path / f"part{i}.csv", "A,B\n" + f"{i}a,{i}b\n" + f"{i}c,{i}d\n")
    ds = read_source(str(tmp_path / "part*.csv"), fmt="csv", block_rows=2)
    assert ds.count() == 6
    # sorted path order => deterministic row order
    assert list(ds.materialize().columns["A"]) == ["0a", "0c", "1a", "1c", "2a", "2c"]


def test_glob_heterogeneous_schemas_union_on_project(tmp_path):
    _write(tmp_path / "s0.csv", "A,B\n1,2\n")
    _write(tmp_path / "s1.csv", "A,C\n3,4\n")
    ds = read_source(str(tmp_path / "s*.csv"), block_rows=4).project("A", "B", "C")
    m = ds.materialize()
    assert list(m.columns["A"]) == ["1", "3"]
    assert list(m.columns["B"]) == ["2", ""]
    assert list(m.columns["C"]) == ["", "4"]


def test_glob_no_match_raises(tmp_path):
    """A typo'd source path must fail loudly (the eager loader's open()
    would), never produce a silently empty KG."""
    with pytest.raises(FileNotFoundError, match="nope"):
        read_source(str(tmp_path / "nope*.csv")).count()
    with pytest.raises(FileNotFoundError, match="nope"):
        list(read_source(str(tmp_path / "nope*.csv")).iter_blocks())


def test_tsv_reader(tmp_path):
    _write(tmp_path / "t.tsv", "A\tB\n1\tx\n2\ty\n")
    m = read_source(str(tmp_path / "t.tsv"), fmt="tsv").materialize()
    assert list(m.columns["B"]) == ["x", "y"]


def test_read_csv_custom_delimiter(tmp_path):
    _write(tmp_path / "t.txt", "A;B\n1;x\n2;y\n")
    m = read_csv(str(tmp_path / "t.txt"), delimiter=";").materialize()
    assert m.schema == ("A", "B")
    assert list(m.columns["B"]) == ["x", "y"]


def test_strict_project_raises_on_missing_column():
    b = Block({"A": np.array(["1"], object)})
    with pytest.raises(KeyError, match="B"):
        b.select(("A", "B"), fill=None)


def test_stream_missing_mapping_column_fails_like_eager(tmp_path):
    """A mapping referencing a column absent from a fixed-schema CSV must
    fail loudly in stream mode (eager raises KeyError), not silently emit
    empty-term triples."""
    from repro.rml.model import (
        LogicalSource, MappingDocument, PredicateObjectMap, TermMap, TriplesMap,
    )

    _write(tmp_path / "t.csv", "A\n1\n2\n")
    doc = MappingDocument(
        {
            "T": TriplesMap(
                name="T",
                source=LogicalSource(path="t.csv"),
                subject=TermMap(template="http://x/{A}"),
                poms=(
                    PredicateObjectMap(
                        predicate="http://x/p",
                        object_map=TermMap(reference="TYPO_COLUMN"),
                    ),
                ),
            )
        }
    )
    with pytest.raises(KeyError, match="TYPO_COLUMN"):
        create_kg(doc, data_root=str(tmp_path))
    with pytest.raises(KeyError, match="TYPO_COLUMN"):
        create_kg(doc, data_root=str(tmp_path), stream=True, block_rows=2)


def test_stream_missing_json_column_fails_like_eager(tmp_path):
    """Union-fill sources (JSON) tolerate per-record heterogeneity, but a
    column absent from EVERY record is a mapping typo and must fail loudly
    in stream mode too (the eager key-union raises table[c] KeyError)."""
    from repro.rml.model import (
        LogicalSource, MappingDocument, PredicateObjectMap, TermMap, TriplesMap,
    )

    _write(tmp_path / "t.jsonl", '{"a": "1"}\n{"a": "2", "b": "x"}\n')
    doc = MappingDocument(
        {
            "T": TriplesMap(
                name="T",
                source=LogicalSource(path="t.jsonl", fmt="json"),
                subject=TermMap(template="http://x/{a}"),
                poms=(
                    PredicateObjectMap(
                        predicate="http://x/p",
                        object_map=TermMap(reference="TYPO_COLUMN"),
                    ),
                ),
            )
        }
    )
    with pytest.raises(KeyError, match="TYPO_COLUMN"):
        create_kg(doc, data_root=str(tmp_path))
    with pytest.raises(KeyError, match="TYPO_COLUMN"):
        create_kg(doc, data_root=str(tmp_path), stream=True, block_rows=2)
    # partial heterogeneity (column "b" in only some records) stays fine
    doc_ok = MappingDocument(
        {
            "T": TriplesMap(
                name="T",
                source=LogicalSource(path="t.jsonl", fmt="json"),
                subject=TermMap(template="http://x/{a}"),
                poms=(
                    PredicateObjectMap(
                        predicate="http://x/p",
                        object_map=TermMap(reference="b"),
                    ),
                ),
            )
        }
    )
    eager = create_kg(doc_ok, data_root=str(tmp_path)).sorted_ntriples()
    streamed = create_kg(
        doc_ok, data_root=str(tmp_path), stream=True, block_rows=1
    ).sorted_ntriples()
    assert eager == streamed


def test_stream_honors_batch_size(tmp_path):
    """batch_size bounds the jitted device batch even in stream mode
    (blocks are split into padded sub-batches)."""
    tb = generator.make_testbed("SOM", 600, 0.25, n_poms=1, seed=4)
    tb.write(str(tmp_path))
    eager = _kg_lines(tb.doc, str(tmp_path))
    streamed = _kg_lines(
        tb.doc, str(tmp_path), stream=True, block_rows=512, batch_size=64
    )
    assert streamed == eager


# ------------------------------------------- incremental dictionary encode


def test_incremental_encode_ids_stable_across_blocks(tmp_path):
    from repro.data.encoder import Dictionary

    _write(tmp_path / "t.csv", "A\n" + "x\ny\nx\nz\nx\n")
    d = Dictionary()
    blocks = list(
        read_csv(str(tmp_path / "t.csv"), block_rows=2).encode(d).iter_blocks()
    )
    ids = np.concatenate([b.columns["A"] for b in blocks])
    assert ids.dtype == np.int32
    assert ids[0] == ids[2] == ids[4]  # same string -> same id across blocks
    assert len({int(ids[0]), int(ids[1]), int(ids[3])}) == 3
    assert list(d.decode(ids)) == ["x", "y", "x", "z", "x"]


def test_literal_path_with_glob_chars(tmp_path):
    """A path that exists literally is one file even if it contains glob
    metacharacters (would otherwise silently read zero rows)."""
    d = tmp_path / "data[v2]"
    d.mkdir()
    _write(d / "t.csv", "A\n1\n2\n")
    assert read_csv(str(d / "t.csv"), block_rows=4).count() == 2


def test_unconsumed_iterator_starts_no_thread(tmp_path):
    """iter_blocks() results that are never drained must not leak a pump
    thread (it starts lazily on first consumption)."""
    import threading

    _write(tmp_path / "t.csv", "A\n1\n2\n")
    before = threading.active_count()
    it = read_csv(str(tmp_path / "t.csv"), block_rows=1).iter_blocks(prefetch=2)
    assert threading.active_count() == before
    assert sum(b.n_rows for b in it) == 2  # and it still works when drained


def test_invalid_block_rows_rejected(tmp_path):
    _write(tmp_path / "t.csv", "A\n1\n")
    with pytest.raises(ValueError, match="block_rows"):
        read_csv(str(tmp_path / "t.csv"), block_rows=0)
    tb = generator.make_testbed("SOM", 10, 0.25)
    with pytest.raises(ValueError, match="block_rows"):
        create_kg(tb.doc, tables={"csv:child.csv": tb.child}, stream=True,
                  block_rows=-1)


def test_constant_terms_stream_matches_eager(tmp_path):
    """Ops that read NO source columns (constant subject + rr:class, and a
    constant object) must still emit triples in stream mode — a zero-column
    projection would otherwise drop every block's row count."""
    from repro.rml.model import (
        LogicalSource, MappingDocument, PredicateObjectMap, TermMap, TriplesMap,
    )

    _write(tmp_path / "t.csv", "A\n1\n2\n3\n")
    doc = MappingDocument(
        {
            "T": TriplesMap(
                name="T",
                source=LogicalSource(path="t.csv"),
                subject=TermMap(constant="http://x/thing"),
                subject_class="http://x/Class",
                poms=(
                    PredicateObjectMap(
                        predicate="http://x/tag",
                        object_map=TermMap(constant="fixed"),
                    ),
                    PredicateObjectMap(
                        predicate="http://x/a",
                        object_map=TermMap(reference="A"),
                    ),
                ),
            )
        }
    )
    eager = create_kg(doc, data_root=str(tmp_path)).sorted_ntriples()
    streamed = create_kg(
        doc, data_root=str(tmp_path), stream=True, block_rows=2
    ).sorted_ntriples()
    assert streamed == eager
    assert any("x/Class" in t for t in eager)
    assert any('"fixed"' in t for t in eager)


def test_distinct_json_iterators_are_distinct_sources(tmp_path):
    """Two triples maps over the same JSON file with different iterators
    must each see their own record stream — in both engines."""
    from repro.rml.model import (
        LogicalSource, MappingDocument, PredicateObjectMap, TermMap, TriplesMap,
    )

    _write(
        tmp_path / "d.json",
        json.dumps(
            {"people": [{"id": "p1"}, {"id": "p2"}], "orders": [{"oid": "o1"}]}
        )
        + "\n",
    )
    maps = {
        "People": TriplesMap(
            name="People",
            source=LogicalSource(path="d.json", fmt="json", iterator="$.people"),
            subject=TermMap(template="http://x/person/{id}"),
            poms=(
                PredicateObjectMap(
                    predicate="http://x/id", object_map=TermMap(reference="id")
                ),
            ),
        ),
        "Orders": TriplesMap(
            name="Orders",
            source=LogicalSource(path="d.json", fmt="json", iterator="$.orders"),
            subject=TermMap(template="http://x/order/{oid}"),
            poms=(
                PredicateObjectMap(
                    predicate="http://x/oid", object_map=TermMap(reference="oid")
                ),
            ),
        ),
    }
    doc = MappingDocument(maps)
    eager = create_kg(doc, data_root=str(tmp_path)).sorted_ntriples()
    streamed = create_kg(
        doc, data_root=str(tmp_path), stream=True, block_rows=2
    ).sorted_ntriples()
    assert eager == streamed
    assert any("person/p1" in t for t in eager)
    assert any("person/p2" in t for t in eager)
    assert any("order/o1" in t for t in eager)
    assert not any("person/o1" in t or "order/p1" in t for t in eager)


# --------------------------------------------------- end-to-end equivalence


def _kg_lines(doc, data_root, **cfg):
    return create_kg(doc, data_root=data_root, **cfg).sorted_ntriples()


@pytest.mark.parametrize("kind", ["SOM", "ORM", "OJM"])
@pytest.mark.parametrize("dup", [0.25, 0.75])
def test_stream_engine_matches_eager_and_naive(tmp_path, kind, dup):
    tb = generator.make_testbed(kind, 1200, dup, n_poms=2, seed=7)
    tb.write(str(tmp_path))
    eager = _kg_lines(tb.doc, str(tmp_path), engine="optimized")
    naive = _kg_lines(tb.doc, str(tmp_path), engine="naive")
    streamed = _kg_lines(
        tb.doc, str(tmp_path), engine="optimized", stream=True, block_rows=256
    )
    assert streamed == eager == naive
    assert len(streamed) > 0


@pytest.mark.parametrize("block_rows", [64, 1200, 4096])
def test_stream_block_rows_invariance(tmp_path, block_rows):
    """Short blocks, exactly-one-block, and bigger-than-source blocks all
    produce the same KG."""
    tb = generator.make_testbed("OJM", 1200, 0.25, n_poms=1, seed=3)
    tb.write(str(tmp_path))
    eager = _kg_lines(tb.doc, str(tmp_path))
    streamed = _kg_lines(tb.doc, str(tmp_path), stream=True, block_rows=block_rows)
    assert streamed == eager


def test_stream_hash_join_strategy(tmp_path):
    tb = generator.make_testbed("OJM", 800, 0.25, n_poms=1, seed=9)
    tb.write(str(tmp_path))
    assert _kg_lines(tb.doc, str(tmp_path), join_strategy="hash", stream=True,
                     block_rows=128) == _kg_lines(tb.doc, str(tmp_path))


def test_stream_never_uses_eager_loaders(tmp_path, monkeypatch):
    """Out-of-core guarantee: stream mode must go through the chunked
    readers only — the eager full-table loaders are never invoked."""
    import repro.data.sources as sources

    tb = generator.make_testbed("OJM", 600, 0.25, n_poms=1, seed=5)
    tb.write(str(tmp_path))

    def boom(*a, **k):
        raise AssertionError("eager loader called in stream mode")

    monkeypatch.setattr(sources, "load_csv", boom)
    monkeypatch.setattr(sources, "load_json", boom)
    monkeypatch.setattr(sources, "load", boom)
    res = create_kg(tb.doc, data_root=str(tmp_path), stream=True, block_rows=128)
    assert res.n_triples > 0
    assert res.engine == "stream"


def test_stream_rejects_naive_engine():
    tb = generator.make_testbed("SOM", 50, 0.25)
    with pytest.raises(ValueError, match="stream"):
        create_kg(tb.doc, tables={"csv:child.csv": tb.child},
                  engine="naive", stream=True)


def test_stream_cli_flags(tmp_path, capsys, monkeypatch):
    from repro.launch import rdfize
    from repro.rml import serializer

    tb = generator.make_testbed("SOM", 300, 0.25, n_poms=1)
    tb.write(str(tmp_path))
    serializer.write_turtle(tb.doc, str(tmp_path / "map.ttl"))
    out = tmp_path / "kg.nt"
    monkeypatch.setattr(
        "sys.argv",
        ["rdfize", "--mapping", str(tmp_path / "map.ttl"),
         "--data-root", str(tmp_path), "--out", str(out),
         "--stream", "--block-rows", "128"],
    )
    rdfize.main()
    assert "stream engine" in capsys.readouterr().out
    assert out.read_text().count("\n") > 0


@pytest.mark.slow
def test_stream_100k_acceptance(tmp_path):
    """Acceptance: a 100K-row testbed through create_kg block-at-a-time,
    byte-identical (sorted triples) to the eager optimized engine."""
    tb = generator.make_testbed("SOM", 100_000, 0.75, n_poms=2, seed=1)
    tb.write(str(tmp_path))
    eager = _kg_lines(tb.doc, str(tmp_path))
    streamed = _kg_lines(tb.doc, str(tmp_path), stream=True, block_rows=1 << 13)
    assert streamed == eager
