"""Per-architecture smoke tests: REDUCED configs of the same family run one
forward/train step on CPU; output shapes asserted, no NaNs (deliverable f).
The FULL configs are exercised only via the dry-run."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import (
    command_r_plus_104b, dbrx_132b, equiformer_v2, gat_cora, gemma_2b,
    meshgraphnet, mixtral_8x7b, nequip, qwen2_5_3b, wide_deep,
)
from repro.data import graphs
from repro.models import recsys, transformer
from repro.models.gnn import common as gnn_common
from repro.models.gnn import equiformer as eq_mod
from repro.models.gnn import gat as gat_mod
from repro.models.gnn import meshgraphnet as mgn_mod
from repro.models.gnn import nequip as nq_mod
from repro.train.optimizer import AdamW
from repro.train.trainer import make_train_step

KEY = jax.random.PRNGKey(0)
LM_SMOKES = {
    "qwen2.5-3b": qwen2_5_3b.smoke_config,
    "gemma-2b": gemma_2b.smoke_config,
    "command-r-plus-104b": command_r_plus_104b.smoke_config,
    "dbrx-132b": dbrx_132b.smoke_config,
    "mixtral-8x7b": mixtral_8x7b.smoke_config,
}


@pytest.mark.parametrize("arch", sorted(LM_SMOKES))
def test_lm_smoke_train_step(arch):
    cfg = LM_SMOKES[arch]()
    params = transformer.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    opt = AdamW(lr=1e-3)
    step = make_train_step(
        lambda p, t, l: transformer.loss_fn(cfg, p, t, l), opt
    )
    state = opt.init(params)
    params2, state2, metrics = jax.jit(step)(params, state, toks, labels)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params actually moved
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(d0, np.float32), np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", sorted(LM_SMOKES))
def test_lm_smoke_decode(arch):
    cfg = LM_SMOKES[arch]()
    params = transformer.init(KEY, cfg)
    cache = transformer.make_cache(cfg, 2, 16)
    toks = jax.random.randint(KEY, (2, 1), 0, cfg.vocab)
    logits, cache2 = jax.jit(
        lambda p, c, t, pos: transformer.decode_step(cfg, p, c, t, pos)
    )(params, cache, toks, jnp.int32(3))
    assert logits.shape == (2, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


GNN_SMOKES = {
    "gat-cora": (gat_cora.smoke_config, gat_mod),
    "meshgraphnet": (meshgraphnet.smoke_config, mgn_mod),
    "nequip": (nequip.smoke_config, nq_mod),
    "equiformer-v2": (equiformer_v2.smoke_config, eq_mod),
}


@pytest.mark.parametrize("arch", sorted(GNN_SMOKES))
@pytest.mark.parametrize("task", ["node_cls", "graph_reg"])
def test_gnn_smoke_train_step(arch, task):
    import dataclasses

    cfg_fn, mod = GNN_SMOKES[arch]
    cfg = cfg_fn()
    n_graphs = 4 if task == "graph_reg" else 1
    cfg = dataclasses.replace(cfg, d_in=12, task=task, n_classes=5)
    b = graphs.random_graph(60, 200, 12, n_classes=5, task=task, n_graphs=n_graphs)
    bj = jax.tree.map(jnp.asarray, b)
    params = mod.init(KEY, cfg)
    opt = AdamW(lr=1e-3)
    step = make_train_step(lambda p, batch: mod.loss_fn(p, cfg, batch, n_graphs), opt)
    params2, _, metrics = jax.jit(step)(params, opt.init(params), bj)
    assert np.isfinite(float(metrics["loss"]))


def test_gnn_respects_edge_mask():
    """Invariance: masked (padding) edges must not change the output."""
    cfg = gat_cora.smoke_config()
    b = graphs.random_graph(40, 100, 32, n_classes=7)
    bj = jax.tree.map(jnp.asarray, b)
    params = gat_mod.init(KEY, cfg)
    out1 = gat_mod.forward(params, cfg, bj)
    # append garbage edges, masked out
    bad = bj._replace(
        edge_src=jnp.concatenate([bj.edge_src, jnp.zeros(10, jnp.int32)]),
        edge_dst=jnp.concatenate([bj.edge_dst, jnp.arange(10, dtype=jnp.int32)]),
        edge_mask=jnp.concatenate([bj.edge_mask, jnp.zeros(10, bool)]),
    )
    out2 = gat_mod.forward(params, cfg, bad)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


def test_widedeep_smoke_train_step():
    cfg = wide_deep.smoke_config()
    params = recsys.init(KEY, cfg)
    rng = np.random.default_rng(0)
    sp = jnp.asarray(rng.integers(0, cfg.vocab_per_field, (16, cfg.n_sparse, 1)).astype(np.int32))
    de = jnp.asarray(rng.normal(size=(16, cfg.n_dense)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 2, 16).astype(np.int32))
    opt = AdamW(lr=1e-3)
    step = make_train_step(lambda p, s, d, l: recsys.loss_fn(p, cfg, s, d, l), opt)
    params2, _, m = jax.jit(step)(params, opt.init(params), sp, de, y)
    assert np.isfinite(float(m["loss"]))
    logits = recsys.forward(params2, cfg, sp, de)
    assert logits.shape == (16,)


def test_widedeep_dedup_matches_plain():
    import dataclasses

    cfg = wide_deep.smoke_config()
    cfg_d = dataclasses.replace(cfg, dedup_cap=64)
    params = recsys.init(KEY, cfg)
    rng = np.random.default_rng(1)
    sp = jnp.asarray(rng.integers(0, 8, (16, cfg.n_sparse, 1)).astype(np.int32))
    de = jnp.asarray(rng.normal(size=(16, cfg.n_dense)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(recsys.forward(params, cfg, sp, de)),
        np.asarray(recsys.forward(params, cfg_d, sp, de)),
        rtol=1e-5,
    )
