"""repro.api: one query surface over every transport — local sessions,
``.kgz`` paths, and the socket server — answering the same
``QueryResult`` and raising the same typed errors.  The parity property
(local rows == remote rows, query by query) is the module's contract."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # test image without hypothesis: seeded-example fallback
    from _hypothesis_shim import given, settings, st

from repro import api
from repro.kg import persist
from repro.kg.store import TripleStore
from repro.live.delta import LiveStore
from repro.serve.server import KGServer

SUBS = [f"<http://ex/s{i}>" for i in range(5)]
PREDS = [f"<http://ex/p{i}>" for i in range(3)]
OBJS = SUBS[:2] + ['"1"', '"2"', '"10"', '"abc"', '""']


def rand_store(seed: int, n_triples: int) -> TripleStore:
    rng = np.random.default_rng(seed)
    triples = {
        (
            SUBS[rng.integers(0, len(SUBS))],
            PREDS[rng.integers(0, len(PREDS))],
            OBJS[rng.integers(0, len(OBJS))],
        )
        for _ in range(n_triples)
    }
    return TripleStore.from_ntriples(sorted(triples))


# queries spanning the algebra: plain BGP, star join, projection+LIMIT,
# OPTIONAL, UNION, GROUP BY-COUNT — every shape must answer identically
# through both transports
PARITY_QUERIES = [
    "SELECT * WHERE { ?s <http://ex/p0> ?o }",
    "SELECT * WHERE { ?s <http://ex/p0> ?o . ?s <http://ex/p1> ?o2 }",
    "SELECT ?s WHERE { ?s <http://ex/p1> ?o } LIMIT 3",
    "SELECT * WHERE { ?s <http://ex/p0> ?o "
    "OPTIONAL { ?s <http://ex/p2> ?h } }",
    "SELECT * WHERE { { ?s <http://ex/p0> ?o } UNION "
    "{ ?s <http://ex/p2> ?o } }",
    "SELECT ?s (COUNT(*) AS ?n) WHERE { ?s ?p ?o } GROUP BY ?s "
    "ORDER BY DESC(?n)",
]


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_local_remote_parity(seed):
    store = rand_store(seed, 30)
    srv = KGServer(store, port=0, linger_ms=1.0, log=False).start()
    try:
        local = api.connect(store)
        with api.connect(f"127.0.0.1:{srv.port}", retry_s=5.0) as remote:
            for qtext in PARITY_QUERIES:
                lr = local.query(qtext)
                rr = remote.query(qtext)
                assert lr.vars == rr.vars, qtext
                assert lr.rows == rr.rows, qtext
                assert lr.n_total == rr.n_total, qtext
                assert lr.agg_vars == rr.agg_vars, qtext
                assert local.explain(qtext) == remote.explain(qtext)
    finally:
        srv.stop()


def test_query_result_surface():
    store = rand_store(11, 40)
    res = api.connect(store).query("SELECT * WHERE { ?s <http://ex/p0> ?o }")
    assert len(res) == len(res.rows) == res.n_total
    assert list(iter(res)) == res.rows
    d = res.to_dict()
    assert d["vars"] == list(res.vars)
    assert d["rows"] == [list(r) for r in res.rows]
    assert d["n_total"] == res.n_total
    assert res.raw is None  # local sessions have no wire reply


def test_local_typed_errors():
    s = api.connect(rand_store(3, 20))
    with pytest.raises(api.QueryParseError):
        s.query("SELECT nonsense {")
    with pytest.raises(api.BadRequestError, match="limit"):
        s.query("SELECT * WHERE { ?s ?p ?o }", limit=-1)
    # a plain TripleStore is read-only; every mutation op is rejected
    assert s.read_only
    for op in (lambda: s.insert([("<a>", "<b>", '"c"')]),
               lambda: s.delete([("<a>", "<b>", '"c"')]),
               s.compact):
        with pytest.raises(api.ReadOnlyError):
            op()
    live = api.connect(LiveStore(rand_store(3, 20)))
    with pytest.raises(api.BadRequestError, match="triples"):
        live.insert([("<only>", "<two>")])
    # every API error is a RuntimeError: pre-hierarchy callers still catch
    assert issubclass(api.KGError, RuntimeError)
    with pytest.raises(api.BadRequestError):
        api.connect(object())


def test_remote_typed_errors():
    store = rand_store(5, 25)
    srv = KGServer(store, port=0, linger_ms=1.0, log=False).start()
    try:
        with api.connect(f"127.0.0.1:{srv.port}", retry_s=5.0) as s:
            with pytest.raises(api.QueryParseError, match="server error"):
                s.query("SELECT nonsense {")
            with pytest.raises(api.BadRequestError, match="limit"):
                s.query("SELECT * WHERE { ?s ?p ?o }", limit=-1)
            with pytest.raises(api.ReadOnlyError) as ei:
                s.insert([("<a>", "<b>", '"c"')])
            assert ei.value.code == "read_only"
    finally:
        srv.stop()
    # the transport error doubles as ConnectionError for legacy callers
    assert issubclass(api.ProtocolError, ConnectionError)


def test_connect_path_arms(tmp_path):
    store = rand_store(7, 30)
    path = str(tmp_path / "t.kgz")
    persist.save(store, path)
    q = "SELECT * WHERE { ?s <http://ex/p0> ?o }"
    want = api.connect(store).query(q).rows

    ro = api.connect(path, read_only=True)
    assert ro.read_only
    assert ro.query(q).rows == want
    with pytest.raises(api.ReadOnlyError):
        ro.insert([("<x>", "<http://ex/p0>", '"y"')])

    rw = api.connect(path)  # mutable: a LiveStore over the loaded chain
    assert not rw.read_only
    r = rw.insert([("<x>", "<http://ex/p0>", '"y"')])
    assert r["inserted"] == 1 and r["generation"] >= 1
    assert rw.query(q).n_total == len(want) + 1
    assert rw.compact()["compacted"]
    assert rw.query(q).n_total == len(want) + 1


def test_shims_route_through_api():
    """kg.query.solve answers over live and plain stores via the same
    LocalSession.execute path (encoded bindings preserved)."""
    from repro.kg.query import decode_bindings, solve_text

    store = rand_store(9, 30)
    b = solve_text(store, "?s <http://ex/p0> ?o")
    want = api.connect(store).query("SELECT * WHERE { ?s <http://ex/p0> ?o }")
    got = [
        (row["?s"], row["?o"]) for row in decode_bindings(store, b)
    ]
    assert got == want.rows and b.n == want.n_total
    live = LiveStore(store)
    live.insert([("<zz>", "<http://ex/p0>", '"live"')])
    b2 = solve_text(live, "?s <http://ex/p0> ?o")
    assert b2.n == b.n + 1
