"""Training substrate: optimizer, microbatching, compression, checkpoint,
fault-tolerance policies."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train import checkpoint, compression, fault
from repro.train.optimizer import AdamW
from repro.train.trainer import make_train_step

KEY = jax.random.PRNGKey(0)


def _quad_loss(params, x, y):
    pred = x @ params["w"] + params["b"]
    return jnp.mean((pred - y) ** 2)


def _toy():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 4)).astype(np.float32))
    w_true = jnp.asarray(rng.normal(size=(4,)).astype(np.float32))
    y = x @ w_true + 0.3
    params = {"w": jnp.zeros(4), "b": jnp.zeros(())}
    return params, x, y


def test_adamw_converges():
    params, x, y = _toy()
    opt = AdamW(lr=5e-2)
    step = jax.jit(make_train_step(_quad_loss, opt))
    state = opt.init(params)
    losses = []
    for _ in range(200):
        params, state, m = step(params, state, x, y)
        losses.append(float(m["loss"]))
    assert losses[-1] < 1e-3 < losses[0]


def test_grad_accum_matches_full_batch():
    params, x, y = _toy()
    opt = AdamW(lr=1e-2, grad_clip=None)
    full = make_train_step(_quad_loss, opt)
    micro = make_train_step(_quad_loss, opt, grad_accum=4)
    p1, s1, m1 = jax.jit(full)(params, opt.init(params), x, y)
    xm = x.reshape(4, 16, 4)
    ym = y.reshape(4, 16)
    p2, s2, m2 = jax.jit(micro)(params, opt.init(params), xm, ym)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), rtol=1e-5)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)


def test_compression_error_feedback_converges():
    params, x, y = _toy()
    opt = AdamW(lr=5e-2)
    step = jax.jit(make_train_step(_quad_loss, opt, compress=True))
    state = opt.init(params)
    err = None
    for _ in range(300):
        params, state, m, err = step(params, state, x, y, error_fb=err)
    assert float(m["loss"]) < 1e-2


def test_compression_bounded_error():
    g = {"a": jnp.asarray(np.random.default_rng(0).normal(size=(128,)).astype(np.float32))}
    cg, err = compression.compress_decompress(g)
    scale = float(jnp.max(jnp.abs(g["a"]))) / 127.0
    assert float(jnp.max(jnp.abs(cg["a"] - g["a"]))) <= scale * 1.01


def test_checkpoint_roundtrip(tmp_path):
    params, x, y = _toy()
    opt = AdamW(lr=1e-2)
    state = opt.init(params)
    tree = {"params": params, "opt": state}
    path = os.path.join(tmp_path, "step_10")
    checkpoint.save(path, tree, step=10)
    like = jax.tree.map(lambda a: jnp.zeros_like(a), tree)
    restored, step = checkpoint.restore(path, like)
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_and_latest(tmp_path):
    tree = {"w": jnp.ones(3)}
    checkpoint.save(os.path.join(tmp_path, "step_1"), tree, step=1)
    checkpoint.save(os.path.join(tmp_path, "step_20"), tree, step=20)
    latest = checkpoint.latest_step_dir(str(tmp_path))
    assert latest.endswith("step_20")


def test_checkpoint_elastic_restore_across_mesh(tmp_path):
    """Write unsharded, restore onto a 1-device 'mesh' sharding (the elastic
    path device_put's through NamedSharding)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import compat_make_mesh

    mesh = compat_make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    path = os.path.join(tmp_path, "step_5")
    checkpoint.save(path, tree, step=5)
    shardings = {"w": NamedSharding(mesh, P("data"))}
    restored, _ = checkpoint.restore(path, tree, shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8))


def test_retry_policy_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("simulated preemption")
        return "ok"

    pol = fault.RetryPolicy(max_retries=3, backoff_s=0.0)
    restores = []
    assert pol.run(flaky, on_failure=lambda a, e: restores.append(a)) == "ok"
    assert calls["n"] == 3 and len(restores) == 2


def test_retry_policy_gives_up():
    pol = fault.RetryPolicy(max_retries=1, backoff_s=0.0)
    with pytest.raises(RuntimeError):
        pol.run(lambda: (_ for _ in ()).throw(RuntimeError("dead")))


def test_straggler_detector():
    det = fault.StragglerDetector(warmup_steps=2, threshold=2.0)
    flags = [det.observe(t) for t in [5.0, 5.0, 0.1, 0.1, 0.1, 0.1, 1.0]]
    assert flags[-1] is True and not any(flags[:-1])
